"""Continuous-batching scheduler: the acceptance contract.

Overlapping mixed-tier requests served through the paged KV cache are
token-for-token identical to running each request *alone* through
PR 3's ``generate()`` on the same physical words (the request's page
placement), greedy and sampled, in every scheduler injection mode,
with and without ECC -- while the decode step compiles exactly once
and its pallas-launch count stays flat as requests are admitted and
retired.  Capacity exhaustion is backpressure, not a crash; the legacy
``rewrite`` oracle is rejected loudly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import CapacityError, MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch
from repro.serving.engine import ServeConfig, generate
from repro.serving.scheduler import ContinuousBatchingScheduler, Request
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
ALL_PCS = tuple(range(VCU128.num_pcs))

_R = np.random.RandomState(7)
# (rid, prompt, max_new_tokens, tier, key seed): three overlapping
# requests with distinct prompt lengths, lifetimes and tiers
REQS = [
    ("a", _R.randint(0, CFG.vocab, (5,)), 4, "cheap", 11),
    ("b", _R.randint(0, CFG.vocab, (9,)), 6, "critical", 22),
    ("c", _R.randint(0, CFG.vocab, (12,)), 8, "cheap", 33),
]


def _plan(v, ecc=False):
    return UndervoltPlan(
        domains={"kv": MemoryDomain("kv", v, ALL_PCS, ecc=ecc)},
        policy={"kv_cache": "kv"}, geometry=VCU128)


def _sc(mode, temperature=0.0, plan=None, method="bitwise", **kw):
    return ServeConfig(max_len=32, max_new_tokens=4,
                       temperature=temperature, undervolt=plan,
                       kv_injection=mode, kv_method=method, **kw)


def _serve(sc, reqs=REQS, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_slots", 8)
    sched = ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc, **kw)
    for rid, toks, n, tier, seed in reqs:
        sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                             tier=tier, key=jax.random.PRNGKey(seed)))
    return sched, sched.run()


def _reference(sc, res, reqs=REQS):
    """Each request alone through PR 3's generate() on its own pages."""
    out = {}
    for rid, toks, n, tier, seed in reqs:
        out[rid] = np.asarray(generate(
            BUNDLE, CFG, PARAMS, {"tokens": jnp.asarray(toks[None])},
            dataclasses.replace(sc, max_new_tokens=n),
            key=jax.random.PRNGKey(seed),
            kv_placement=res[rid].placement))
    return out


@pytest.mark.parametrize("mode,temperature",
                         [("read", 0.0), ("read", 0.7), ("write", 0.0)])
def test_scheduler_matches_standalone_generate(mode, temperature):
    """The tentpole contract, deep in the collapse regime: overlapped
    mixed-tier serving == per-request standalone decode, bit for bit."""
    sc = _sc(mode, temperature, _plan(0.86))
    sched, res = _serve(sc)
    assert sched.peak_active >= 3, sched.stats
    assert len(sched.traces) == 1, sched.stats
    refs = _reference(sc, res)
    for rid, toks, n, tier, seed in REQS:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=f"{rid} {mode}")
    # the undervolted cache really faults: clean serving disagrees
    clean_sched, clean = _serve(_sc(mode, temperature, None))
    assert any((clean[rid].tokens != res[rid].tokens).any()
               for rid, *_ in REQS)


@pytest.mark.parametrize("mode", ["read", "write"])
def test_scheduler_matches_standalone_ecc(mode):
    sc = _sc(mode, 0.0, _plan(0.86, ecc=True), method="word")
    sched, res = _serve(sc)
    refs = _reference(sc, res)
    for rid, *_ in REQS:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=rid)


def test_scheduler_matches_standalone_word_regime():
    """~1e-4 rates (word path): faults are sparse enough that tokens
    survive -- the equality is then a statement about live numerics,
    not about mutually NaN-ed logits."""
    sc = _sc("read", 0.0, _plan(0.88), method="word")
    sched, res = _serve(sc)
    refs = _reference(sc, res)
    for rid, *_ in REQS:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=rid)


def test_clean_pool_matches_clean_generate():
    """Without an undervolt plan the paged path is pure serving
    mechanics and must reproduce plain generate()."""
    sc = _sc("auto", 0.0, None)
    sched, res = _serve(sc)
    for rid, toks, n, tier, seed in REQS:
        ref = np.asarray(generate(
            BUNDLE, CFG, PARAMS, {"tokens": jnp.asarray(toks[None])},
            dataclasses.replace(sc, max_new_tokens=n),
            key=jax.random.PRNGKey(seed)))
        np.testing.assert_array_equal(ref, res[rid].tokens, err_msg=rid)


def test_churn_backpressure_and_page_recycling():
    """Six requests through two slots and eight pages: admission waits
    for capacity (never crashes), retired pages are recycled for new
    tenants, every request still matches its standalone replay, and
    the whole churn rides ONE compiled decode step."""
    reqs = [(i, _R.randint(0, CFG.vocab, (4 + i,)), 3 + (i % 3),
             "cheap" if i % 2 else "hedged", 7 * i + 1)
            for i in range(6)]
    sc = _sc("write", 0.0, _plan(0.86))
    sched, res = _serve(sc, reqs=reqs, num_slots=2, num_pages=8)
    assert len(res) == 6
    assert sched.peak_active == 2 and sched.admitted == 6
    assert len(sched.traces) == 1, sched.stats
    assert sched.pool.free_pages == 8
    refs = _reference(sc, res, reqs=reqs)
    for rid, *_ in reqs:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=str(rid))


def test_step_pallas_launch_budget_flat():
    """One fused paged-attention launch per decode step -- independent
    of pool size, slot count and injection mode (write-path injection
    is pure jnp gather/scatter)."""
    counts = {}
    for mode in ("read", "write"):
        for num_pages, num_slots in ((8, 2), (24, 6)):
            sc = _sc(mode, 0.0, _plan(0.88), method="word")
            sched = ContinuousBatchingScheduler(
                BUNDLE, CFG, PARAMS, sc, num_slots=num_slots,
                num_pages=num_pages, page_slots=8)
            jaxpr = jax.make_jaxpr(sched._step_fn)(
                PARAMS, sched.state, jnp.float32(0.88))
            counts[(mode, num_pages)] = arena.count_pallas_calls(
                jaxpr.jaxpr)
    assert set(counts.values()) == {1}, counts


def test_impossible_request_raises_capacity_error():
    sc = _sc("read", 0.0, _plan(0.88), method="word")
    sched = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, sc, num_slots=2, num_pages=2, page_slots=8)
    sched.submit(Request("x", REQS[0][1], 2, "cheap"))
    with pytest.raises(CapacityError):
        sched.run()                   # needs 4 pages, pool has 2


def test_zero_token_requests_rejected_at_submit():
    """Degenerate requests are rejected before any pages are allocated
    (an admission-time failure would leak the request's pool pages)."""
    sc = _sc("read", 0.0, _plan(0.88), method="word")
    sched = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, sc, num_slots=2, num_pages=8, page_slots=8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.submit(Request("z", REQS[0][1], 0, "cheap"))
    assert not sched.queue and sched.pool.free_pages == 8


def test_rewrite_mode_rejected_loudly():
    sc = _sc("rewrite", 0.0, _plan(0.88))
    with pytest.raises(ValueError, match="rewrite"):
        ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc,
                                    num_slots=2, num_pages=8,
                                    page_slots=8)
    # the standalone engine rejects rewrite on paged placements too
    sc_ok = _sc("read", 0.0, _plan(0.88), method="word")
    _, res = _serve(sc_ok, reqs=REQS[:1], num_slots=1, num_pages=4)
    with pytest.raises(ValueError, match="rewrite"):
        generate(BUNDLE, CFG, PARAMS,
                 {"tokens": jnp.asarray(REQS[0][1][None])},
                 dataclasses.replace(_sc("rewrite", 0.0, _plan(0.88)),
                                     max_new_tokens=4),
                 kv_placement=res["a"].placement)
    # a placement exported for one request cannot address a batch-2
    # cache: mis-sized overrides raise instead of silently mis-aiming
    # the fault injection
    with pytest.raises(ValueError, match="does not fit"):
        generate(BUNDLE, CFG, PARAMS,
                 {"tokens": jnp.zeros((2, 4), jnp.int32)},
                 dataclasses.replace(sc_ok, max_new_tokens=1),
                 kv_placement=res["a"].placement)


def test_governor_replans_voltage_at_admission():
    plan = _plan(0.91)
    gov = plan.make_governor("kv", mode="rate", tolerable_rate=1e-3,
                             v_lo=0.87)
    sc = ServeConfig(max_len=32, max_new_tokens=3, undervolt=plan,
                     governor=gov, kv_injection="read",
                     kv_method="bitwise")
    sched, res = _serve(sc, reqs=[(r, t, 3, "cheap", s)
                                  for r, t, n, _, s in REQS])
    assert len(res) == 3
    # the governor walked the domain off its configured voltage, and
    # the (traced-voltage) step still compiled exactly once
    assert sched.stats["voltage"] != pytest.approx(0.91)
    assert len(sched.traces) == 1, sched.stats

    with pytest.raises(ValueError, match="kv_method='auto'"):
        ContinuousBatchingScheduler(
            BUNDLE, CFG, PARAMS,
            dataclasses.replace(sc, kv_method="auto"),
            num_slots=2, num_pages=8, page_slots=8)
    with pytest.raises(ValueError, match="mutually exclusive"):
        ContinuousBatchingScheduler(
            BUNDLE, CFG, PARAMS,
            dataclasses.replace(sc, kv_voltage=0.9),
            num_slots=2, num_pages=8, page_slots=8)


# ---------------------------------------------------------------------------
# chunked prefill + reliability-pinned copy-on-write prefix sharing
# ---------------------------------------------------------------------------

SYS = _R.randint(0, CFG.vocab, (11,))         # shared "system prompt"
_T0 = _R.randint(0, CFG.vocab, (4,))
P0 = np.concatenate([SYS, _T0])               # creator prompt, 15 tokens
TENANTS = [
    # rid, prompt, n_new, tier, seed
    ("t1", np.concatenate([SYS, _R.randint(0, CFG.vocab, (2,))]), 4,
     "cheap", 41),                            # page-aligned match (8)
    ("t2", np.concatenate([P0, _R.randint(0, CFG.vocab, (4,))]), 4,
     "critical", 42),                         # longer prompt, mixed tiers
    ("t3", P0.copy(), 4, "cheap", 43),        # exact match: fork + last
]
CREATOR = [("t0", P0, 4, "cheap", 40)]


def _serve_waves(sc, waves, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_slots", 8)
    sched = ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc, **kw)
    for wave in waves:
        for rid, toks, n, tier, seed in wave:
            sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                                 tier=tier, key=jax.random.PRNGKey(seed)))
        sched.run()
    return sched, sched.results


@pytest.mark.parametrize("mode,temperature,ecc",
                         [("read", 0.0, False), ("read", 0.7, False),
                          ("write", 0.0, False), ("read", 0.0, True),
                          ("write", 0.0, True)])
def test_prefix_sharing_matches_standalone(mode, temperature, ecc):
    """Tenants mapping a cached prefix read-only -- page-aligned, COW-
    forked boundary page, and exact-prompt recompute -- are each bit-
    identical to their solo generate() replay on the same physical
    pages, in every injection mode, sampled and greedy, ECC on/off."""
    sc = _sc(mode, temperature, _plan(0.86, ecc=ecc),
             method=("word" if ecc else "bitwise"), share_prefix=True)
    sched, res = _serve_waves(sc, [CREATOR, TENANTS])
    assert len(sched.traces) == 1, sched.stats
    for rid, *_ in TENANTS:
        assert res[rid].pages_shared >= 1, (rid, res[rid])
        # strictly fewer fresh pages than a no-sharing admission
        fresh = sched.pool.n_logical_pages - res[rid].pages_shared
        assert fresh < sched.pool.n_logical_pages
    refs = _reference(sc, res, reqs=CREATOR + TENANTS)
    for rid, *_ in CREATOR + TENANTS:
        np.testing.assert_array_equal(refs[rid], res[rid].tokens,
                                      err_msg=f"{rid} {mode} ecc={ecc}")


def test_shared_pages_pinned_to_most_reliable_strong_pages():
    """Pages that may be published as shared prefixes are allocated
    under the strictest tier: weak-free, most-reliable-first, agreeing
    with the fault map's pseudo-channel reliability order."""
    sc = _sc("read", 0.0, _plan(0.86), share_prefix=True)
    sched = ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc,
                                        num_slots=4, num_pages=16,
                                        page_slots=8)
    pool = sched.pool
    assert len(pool._weak) >= 1, "fault map should make pages weak"
    best = list(pool._strong[:2])       # most-reliable strong pages
    rid, toks, n, tier, seed = CREATOR[0]
    sched.submit(Request(rid=rid, tokens=toks, max_new_tokens=n,
                         tier=tier, key=jax.random.PRNGKey(seed)))
    sched.run()
    shared = [p for p in range(pool.num_pages) if pool.is_shared(p)]
    assert sorted(shared) == sorted(best), (shared, best)
    assert not any(p in pool._weak_set for p in shared)
    # the pool's page ordering IS the fault map's reliability order:
    # a page's rate is its worst pseudo-channel's predicted rate, and
    # pc rates sorted ascending reproduce reliability_order
    fmap = pool.faultmap
    v = pool.domain.voltage
    np.testing.assert_array_equal(
        fmap.reliability_order(v),
        np.argsort(fmap.pc_total_rate(v), kind="stable"))
    rates = fmap.predicted_rates(v)
    pcs = {int(c) for leaf in pool.leaves if leaf.which == "k"
           for c in leaf.page_pc[:, shared].reshape(-1)}
    strong_rates = [pool._rate[p] for p in pool._strong]
    assert all(rates[c] <= (max(strong_rates) if strong_rates else 0)
               for c in pcs)


def test_prefix_pages_recycled_for_later_tenants():
    """A tenant admitted after creator AND earlier tenants retired
    still maps the cached prefix pages (the cache's own holds keep
    them alive), and evicting the cache returns every page."""
    sc = _sc("read", 0.0, _plan(0.86), share_prefix=True)
    sched, res = _serve_waves(sc, [CREATOR, TENANTS[:2]])
    shared_page = int(res["t0"].page_ids[0])
    late = ("t9", np.concatenate([SYS, _R.randint(0, CFG.vocab, (3,))]),
            3, "cheap", 99)
    sched.submit(Request(rid=late[0], tokens=late[1],
                         max_new_tokens=late[2], tier=late[3],
                         key=jax.random.PRNGKey(late[4])))
    sched.run()
    assert sched.results["t9"].pages_shared >= 1
    assert int(sched.results["t9"].page_ids[0]) == shared_page
    np.testing.assert_array_equal(
        _reference(sc, sched.results, reqs=[late])["t9"],
        sched.results["t9"].tokens)
    # drain the prefix cache: every page returns to the free lists
    while sched.pool.evict_prefix():
        pass
    assert sched.pool.shared_pages == 0
    assert sched.pool.free_pages == 16


def test_traces_flat_across_distinct_lengths_with_ttft():
    """>= 4 distinct prompt lengths ride ONE compiled mixed step (no
    per-length prefill program exists anymore), and time-to-first-token
    is the chunk arithmetic: ceil(prompt_len / prefill_chunk) steps."""
    reqs = [(f"L{ln}", _R.randint(0, CFG.vocab, (ln,)), 3, "cheap",
             3 * ln) for ln in (3, 5, 9, 14, 17)]
    sc = _sc("read", 0.0, _plan(0.88), method="word")
    sched, res = _serve(sc, reqs=reqs)
    assert len(sched.traces) == 1, sched.stats
    for rid, toks, n, _, _ in reqs:
        assert res[rid].tokens.shape == (1, n)
        assert res[rid].ttft_steps == -(-len(toks) // sched.chunk), (
            rid, res[rid].ttft_steps, sched.chunk)


def test_overlong_prompts_rejected_at_submit():
    sc = _sc("read", 0.0, _plan(0.88), method="word")
    sched = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, sc, num_slots=2, num_pages=8, page_slots=8)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request("long", np.zeros(33, np.int32), 2, "cheap"))
    with pytest.raises(ValueError, match="empty"):
        sched.submit(Request("nil", np.zeros(0, np.int32), 2, "cheap"))
    assert not sched.queue
