"""Per-kernel tests: bitflip Pallas kernel vs. pure-jnp oracle.

The kernel runs in interpret mode on CPU; parity with ref.py is exact
(integer equality), per the guide's kernel-testing contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.kernels.bitflip import ops

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


def _bits(x):
    """Bit-pattern view for comparisons (NaN-safe)."""
    return np.asarray(jax.lax.bitcast_convert_type(
        x, {2: jnp.uint16, 4: jnp.uint32, 1: jnp.uint8}[x.dtype.itemsize]))


@pytest.mark.parametrize("shape", [(64,), (1000, 7), (16, 8, 33), (4095,),
                                   (4096,), (4097,), (3, 1, 5)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("method", ["word", "bitwise"])
def test_kernel_matches_ref(shape, dtype, method):
    thr = FMAP.thresholds(0.86 if method == "bitwise" else 0.90, pc=4)
    if jnp.issubdtype(dtype, jnp.floating):
        x = jnp.asarray(np.random.RandomState(0).rand(*shape), dtype)
    else:
        x = jnp.asarray(np.random.RandomState(0).randint(-100, 100, shape),
                        dtype)
    y_kernel = ops.inject(x, thresholds=thr, seed=11, base_word=8192,
                          method=method)
    y_ref = ops.inject(x, thresholds=thr, seed=11, base_word=8192,
                       method=method, use_ref=True)
    assert y_kernel.shape == x.shape and y_kernel.dtype == x.dtype
    np.testing.assert_array_equal(_bits(y_kernel), _bits(y_ref))


def test_word_path_rate_matches_model():
    thr = FMAP.thresholds(0.90, pc=18)
    n = 1 << 21
    z = jnp.zeros((n,), jnp.uint32)
    out = ops.inject_u32(z, thresholds=thr, seed=3)
    observed = float(jnp.sum(jax.lax.population_count(out))) / (n * 32)
    expected = float(FMAP.pc_rates(0.90)[0][18])  # 0->1 on zeros
    assert observed == pytest.approx(expected, rel=0.25)


def test_bitwise_path_rate_matches_model():
    thr = FMAP.thresholds(0.88, pc=4)
    n = 1 << 20
    z = jnp.zeros((n,), jnp.uint32)
    out = ops.inject_u32(z, thresholds=thr, seed=3, method="bitwise")
    observed = float(jnp.sum(jax.lax.population_count(out))) / (n * 32)
    expected = float(FMAP.pc_rates(0.88)[0][4])
    assert observed == pytest.approx(expected, rel=0.15)


def test_asymmetry_observed():
    # C6: more 0->1 than 1->0 flips at the same voltage.
    thr = FMAP.thresholds(0.88, pc=4)
    n = 1 << 20
    zeros = jnp.zeros((n,), jnp.uint32)
    ones = jnp.full((n,), np.uint32(0xFFFFFFFF))
    f01 = float(jnp.sum(jax.lax.population_count(
        ops.inject_u32(zeros, thresholds=thr, seed=3, method="bitwise"))))
    f10 = float(jnp.sum(jax.lax.population_count(
        ops.inject_u32(ones, thresholds=thr, seed=3, method="bitwise")
        ^ ones)))
    assert f01 / f10 == pytest.approx(1.21, rel=0.1)


def test_persistent_across_calls():
    thr = FMAP.thresholds(0.89, pc=7)
    x = jnp.asarray(np.random.RandomState(5).rand(4096 * 2), jnp.float32)
    a = ops.inject(x, thresholds=thr, seed=9, base_word=4096)
    b = ops.inject(x, thresholds=thr, seed=9, base_word=4096)
    np.testing.assert_array_equal(_bits(a), _bits(b))


@pytest.mark.parametrize("method,volts", [
    ("word", (0.93, 0.91, 0.89, 0.87)),
    ("bitwise", (0.89, 0.87, 0.85)),
])
def test_monotone_fault_sets_in_voltage(method, volts):
    """Stuck bits at a higher voltage stay stuck at every lower voltage.

    Guaranteed within one injection method (the two methods use
    independent random streams, so crossing the auto-dispatch boundary
    reshuffles identities while preserving rates -- documented behavior).
    """
    n = 1 << 19
    zeros = jnp.zeros((n,), jnp.uint32)
    prev = np.zeros((n,), np.uint32)
    for v in volts:
        thr = FMAP.thresholds(v, pc=19)
        out = np.asarray(ops.inject_u32(zeros, thresholds=thr, seed=1,
                                        method=method))
        assert (prev & ~out).sum() == 0, f"fault lost going down to {v}"
        prev = out


def test_clustering_observed():
    """C9: faults concentrate in weak rows."""
    thr = FMAP.thresholds(0.90, pc=20)
    n = 1 << 20
    z = jnp.zeros((n,), jnp.uint32)
    out = np.asarray(ops.inject_u32(z, thresholds=thr, seed=2))
    words_per_row = 1 << thr.words_per_row_log2
    per_row = out.reshape(-1, words_per_row)
    row_has_fault = (per_row != 0).any(axis=1)
    faults_per_row = np.unpackbits(
        per_row.view(np.uint8), axis=1).sum(axis=1)
    # the top 10% of rows should hold the large majority of the faults
    top = np.sort(faults_per_row)[::-1]
    k = max(1, int(0.1 * len(top)))
    assert top[:k].sum() > 0.7 * top.sum()
    assert row_has_fault.mean() < 0.3


def test_different_seeds_differ():
    thr = FMAP.thresholds(0.89, pc=7)
    z = jnp.zeros((1 << 18,), jnp.uint32)
    a = ops.inject_u32(z, thresholds=thr, seed=1)
    b = ops.inject_u32(z, thresholds=thr, seed=2)
    assert not bool(jnp.all(a == b))
