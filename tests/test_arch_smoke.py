"""Per-architecture smoke tests: reduced config, one train step + one
decode step on CPU, asserting output shapes and no NaNs (assignment
requirement (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS
from repro.models.base import get_arch, init_params

BATCH, SEQ = 2, 16


def _batch(cfg, key):
    b = {"tokens": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            key, (BATCH, cfg.enc_len, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            key, (BATCH, cfg.enc_len, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    bundle = get_arch(arch)
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(
        lambda p, b: bundle.module.forward_train(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.jit(jax.grad(
        lambda p, b: bundle.module.forward_train(p, b, cfg)[0]))(params,
                                                                 batch)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn) and gn > 0.0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_serve_smoke(arch):
    bundle = get_arch(arch)
    cfg = bundle.reduced
    params = init_params(bundle.module.param_specs(cfg),
                         jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_len = SEQ + 8 + (cfg.enc_len if cfg.family == "vlm" else 0)
    logits, cache = jax.jit(
        lambda p, b: bundle.module.prefill(p, b, cfg, max_len))(params,
                                                                batch)
    assert logits.shape == (BATCH, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    pos0 = SEQ + (cfg.enc_len if cfg.family == "vlm" else 0)
    step = jax.jit(
        lambda p, c, t, pos: bundle.module.decode_step(
            p, c, t, pos, cfg))
    tok = batch["tokens"][:, -1:]
    for i in range(2):
        logits, cache = step(params, cache, {"tokens": tok},
                             jnp.int32(pos0 + i))
        assert logits.shape == (BATCH, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), arch
        tok = jnp.argmax(logits, -1, keepdims=True).astype(jnp.int32)


def test_full_configs_match_assignment():
    """The full configs carry the exact pool numbers."""
    import numpy as np
    from repro.models.base import count_params
    expect = {
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }
    for arch, (nl, dm, nh, nkv, dff, voc) in expect.items():
        cfg = get_arch(arch).cfg
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (nl, dm, nh, nkv, dff, voc), arch

    # spot-check parameter counts against the names (order of magnitude)
    n = count_params(get_arch("llama3-8b").module.param_specs(
        get_arch("llama3-8b").cfg))
    assert 7e9 < n < 9e9, n
    n = count_params(get_arch("deepseek-v2-236b").module.param_specs(
        get_arch("deepseek-v2-236b").cfg))
    assert 2.0e11 < n < 2.6e11, n
