"""Unit tests: the power model reproduces the paper's headline numbers."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.faultmodel import V_CRITICAL, V_MIN, V_NOM
from repro.core.voltage import DEFAULT_POWER_MODEL as P, P_IDLE_FRAC


def test_guardband_savings_1_5x():
    # C2: 1.5x power savings at the bottom of the guardband.
    assert float(P.savings(V_MIN)) == pytest.approx(1.5, abs=0.01)


def test_deep_undervolt_savings_2_3x():
    # C3: 2.3x total savings at 0.85 V.
    assert float(P.savings(0.85)) == pytest.approx(2.3, abs=0.05)


def test_savings_independent_of_utilization():
    # C2: "the amount of power savings is independent of the bandwidth
    # utilization" -- undervolting does not touch bandwidth.
    base = float(P.savings(V_MIN, 1.0))
    for util in (0.0, 0.25, 0.5, 0.75):
        assert float(P.savings(V_MIN, util)) == pytest.approx(base, rel=1e-5)
    base85 = float(P.savings(0.85, 1.0))
    for util in (0.0, 0.5):
        assert float(P.savings(0.85, util)) == pytest.approx(base85, rel=1e-5)


def test_idle_power_one_third():
    # C10: idle HBM burns ~1/3 of full-load power.
    assert float(P.power(V_NOM, 0.0)) == pytest.approx(P_IDLE_FRAC, rel=1e-6)
    assert float(P.power(V_NOM, 1.0)) == pytest.approx(1.0, rel=1e-6)


def test_alpha_clf_flat_in_guardband_drops_below():
    # Fig. 3: alpha*C_L*f within 3% of nominal above 0.98 V, ~14% lower
    # at 0.85 V.
    for v in (1.2, 1.1, 1.0, 0.98):
        assert float(P.alpha_clf(v)) == pytest.approx(1.0, abs=0.03)
    assert 1.0 - float(P.alpha_clf(0.85)) == pytest.approx(0.14, abs=0.01)


@hypothesis.given(v=st.floats(min_value=V_CRITICAL, max_value=V_NOM),
                  util=st.floats(min_value=0.0, max_value=1.0))
@hypothesis.settings(max_examples=60, deadline=None)
def test_power_monotone_in_voltage_and_util(v, util):
    assert float(P.power(v, util)) <= float(P.power(V_NOM, util)) + 1e-9
    assert float(P.power(v, util)) <= float(P.power(v, 1.0)) + 1e-9
    assert float(P.power(v, util)) > 0.0


def test_quadratic_scaling_in_guardband():
    # Eq. (1): pure V^2 inside the guardband (no stuck bits).
    for v in (1.1, 1.05, 1.0, 0.98):
        expected = (v / V_NOM) ** 2
        assert float(P.power(v, 1.0)) == pytest.approx(expected, rel=1e-5)
