"""Unit tests: the three-factor trade-off solver (paper section III-C).

The solver is now a vectorized float32 frontier; the float64 numpy
oracle (:func:`repro.core.tradeoff.oracle_point`) is the independent
implementation the property tests hold it to, and the paper's four
worked examples are regression-pinned.
"""
import numpy as np
import pytest

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, oracle_point, voltage_grid

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:          # pragma: no cover - exercised without the dep
    hypothesis = st = None

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
SOLVER = TradeoffSolver(FMAP)


def test_voltage_grid_is_papers_sweep():
    g = voltage_grid()
    assert g[0] == 1.2 and g[-1] == 0.81
    assert len(g) == 40
    assert np.allclose(np.diff(g), -0.01)


def test_zero_tolerance_full_capacity_needs_guardband():
    # "Applications that cannot tolerate any faults and need the entire
    #  8GB are restricted to the guardband region" -> 1.5x at 0.98 V.
    p = SOLVER.solve(VCU128.total_bytes, 0.0)
    assert p.voltage == pytest.approx(0.98)
    assert p.savings == pytest.approx(1.5, abs=0.01)
    assert len(p.pc_ids) == 32
    assert p.worst_pc_rate == 0.0


def test_zero_tolerance_small_capacity_goes_deeper():
    # "up to 1.6X power savings ... by using only 7 fault-free PCs
    #  operating at 0.95V."
    p = SOLVER.solve(7 * VCU128.bytes_per_pc, 0.0)
    assert p.voltage <= 0.96
    assert p.savings >= 1.55
    assert p.worst_pc_rate * VCU128.bits_per_pc < 1.0


def test_half_capacity_1e6_rate():
    # "an application that can tolerate a 1e-6 fault rate and requires
    #  only half of the total memory capacity can push the voltage down
    #  to ~0.90V and save power by a factor of about 1.8X."
    p = SOLVER.solve(VCU128.total_bytes // 2, 1e-6)
    assert p.voltage == pytest.approx(0.90, abs=0.015)
    assert p.savings == pytest.approx(1.8, abs=0.1)


def test_deep_savings_with_capacity_sacrifice():
    # "2.3X power savings is possible by sacrificing some memory space
    #  while the remaining memory space can work with 0% to 50% fault
    #  rate" -- at 0.85 V some PCs are below a 50% rate.
    p = SOLVER.point(0.85, 0.5, VCU128.bytes_per_pc)
    if p is not None:
        assert p.savings == pytest.approx(2.3, abs=0.06)


def test_infeasible_raises():
    with pytest.raises(ValueError):
        SOLVER.solve(VCU128.total_bytes * 2, 0.0)


def test_solution_monotonicity():
    """Looser constraints never yield worse savings (solver invariant)."""
    s_strict = SOLVER.solve(VCU128.total_bytes, 0.0).savings
    s_cap = SOLVER.solve(VCU128.total_bytes // 2, 0.0).savings
    s_rate = SOLVER.solve(VCU128.total_bytes, 1e-4).savings
    assert s_cap >= s_strict - 1e-9
    assert s_rate >= s_strict - 1e-9


def test_fig6_matrix_shape_and_monotonicity():
    rates = [0.0, 1e-7, 1e-5, 1e-3]
    m = SOLVER.fig6_matrix(rates)
    grid = voltage_grid()
    for t in rates:
        assert len(m[t]) == len(grid)
    # at every voltage, a looser tolerance admits >= as many PCs
    for i in range(len(grid)):
        col = [m[t][i] for t in rates]
        assert col == sorted(col)


def test_pareto_frontier():
    pts = SOLVER.pareto(1e-6)
    # savings grow as voltage drops; capacity shrinks (or holds)
    for a, b in zip(pts, pts[1:]):
        assert b.voltage < a.voltage
        assert b.savings >= a.savings
        assert b.capacity_bytes <= a.capacity_bytes


# ---- vectorized frontier vs. the float64 numpy oracle ---------------------

def _usable_bounds(fmap, v, tol, slack=1e-4):
    """(lo, hi) bounds on the usable-PC count, leaving ``slack`` relative
    margin around the threshold so float32/float64 rounding of rates that
    land exactly on the boundary cannot flip the comparison."""
    rates = fmap.pc_total_rate(v)
    if tol <= 0.0:
        crit = rates * fmap.geometry.bits_per_pc
        return int((crit < 1.0 - slack).sum()), int((crit < 1.0 + slack).sum())
    return (int((rates <= tol * (1.0 - slack)).sum()),
            int((rates <= tol * (1.0 + slack)).sum()))


def _check_frontier_against_oracle(fmap, tolerances, grid):
    solver = TradeoffSolver(fmap)
    for tol in tolerances:
        f = solver.frontier(grid, tol)
        num = np.asarray(f.num_usable)
        savings = np.asarray(f.savings)
        for i, v in enumerate(grid):
            lo, hi = _usable_bounds(fmap, float(v), tol)
            assert lo <= int(num[i]) <= hi, (tol, v, lo, int(num[i]), hi)
            o = oracle_point(fmap, float(v), tol, 0)
            if o is not None:
                assert savings[i] == pytest.approx(o.savings, rel=1e-3)
                if lo == hi:     # comfortably off the threshold boundary
                    assert int(num[i]) == len(o.pc_ids)
                    p = solver.point(float(v), tol, 0)
                    assert p is not None
                    assert set(p.pc_ids) == set(o.pc_ids)
                    assert p.worst_pc_rate == pytest.approx(
                        o.worst_pc_rate, rel=1e-3, abs=1e-12)


def test_frontier_matches_oracle_default_map():
    grid = voltage_grid()
    _check_frontier_against_oracle(
        FMAP, (0.0, 1e-8, 1e-6, 1e-4, 1e-2, 0.5), grid)


@pytest.mark.skipif(hypothesis is None, reason="hypothesis not installed")
def test_frontier_matches_oracle_random_maps():
    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(seed=st.integers(min_value=0, max_value=2**16),
                      tol=st.sampled_from([0.0, 1e-7, 1e-5, 1e-3, 0.3]))
    def run(seed, tol):
        fmap = FaultMap.from_seed(VCU128, seed=seed)
        grid = voltage_grid()[::4]       # every 4th point keeps it fast
        _check_frontier_against_oracle(fmap, (tol,), grid)

    run()


def test_solve_matches_oracle_scan():
    """Vectorized solve() == lowest-voltage-first scan of the oracle."""
    for req, tol in ((VCU128.total_bytes, 0.0),
                     (7 * VCU128.bytes_per_pc, 0.0),
                     (VCU128.total_bytes // 2, 1e-6),
                     (VCU128.bytes_per_pc, 1e-3)):
        p = SOLVER.solve(req, tol)
        for v in np.sort(voltage_grid()):
            o = oracle_point(FMAP, float(v), tol, req)
            if o is not None:
                break
        assert p.voltage == pytest.approx(o.voltage)
        assert p.savings == pytest.approx(o.savings, rel=1e-3)
        assert p.capacity_bytes == o.capacity_bytes


# ---- the paper's four worked examples, regression-pinned ------------------

def test_paper_worked_examples_pinned():
    # 1.5x at 0.98 V: zero faults + full capacity (guardband only)
    p = SOLVER.solve(VCU128.total_bytes, 0.0)
    assert (p.voltage, len(p.pc_ids)) == (pytest.approx(0.98), 32)
    assert p.savings == pytest.approx(1.5, abs=0.01)
    # 1.6x at 0.95 V: zero faults, 7 fault-free PCs
    p = SOLVER.solve(7 * VCU128.bytes_per_pc, 0.0)
    assert p.voltage == pytest.approx(0.95)
    assert p.savings == pytest.approx(1.6, abs=0.01)
    # ~1.8x at ~0.90 V: 1e-6 tolerable rate, half capacity
    p = SOLVER.solve(VCU128.total_bytes // 2, 1e-6)
    assert p.voltage == pytest.approx(0.90, abs=0.015)
    assert p.savings == pytest.approx(1.8, abs=0.1)
    # 2.3x at 0.85 V: deep undervolt with capacity sacrifice -- pin the
    # power factor on the frontier (the calibrated map's PCs saturate
    # past 50% there, so the usable set may be empty; the savings pin is
    # the paper's headline number)
    f = SOLVER.frontier(np.asarray([0.85]), 0.5)
    assert float(f.savings[0]) == pytest.approx(2.3, abs=0.06)
