"""Unit tests: the three-factor trade-off solver (paper section III-C)."""
import numpy as np
import pytest

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.core.tradeoff import TradeoffSolver, voltage_grid

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)
SOLVER = TradeoffSolver(FMAP)


def test_voltage_grid_is_papers_sweep():
    g = voltage_grid()
    assert g[0] == 1.2 and g[-1] == 0.81
    assert len(g) == 40
    assert np.allclose(np.diff(g), -0.01)


def test_zero_tolerance_full_capacity_needs_guardband():
    # "Applications that cannot tolerate any faults and need the entire
    #  8GB are restricted to the guardband region" -> 1.5x at 0.98 V.
    p = SOLVER.solve(VCU128.total_bytes, 0.0)
    assert p.voltage == pytest.approx(0.98)
    assert p.savings == pytest.approx(1.5, abs=0.01)
    assert len(p.pc_ids) == 32
    assert p.worst_pc_rate == 0.0


def test_zero_tolerance_small_capacity_goes_deeper():
    # "up to 1.6X power savings ... by using only 7 fault-free PCs
    #  operating at 0.95V."
    p = SOLVER.solve(7 * VCU128.bytes_per_pc, 0.0)
    assert p.voltage <= 0.96
    assert p.savings >= 1.55
    assert p.worst_pc_rate * VCU128.bits_per_pc < 1.0


def test_half_capacity_1e6_rate():
    # "an application that can tolerate a 1e-6 fault rate and requires
    #  only half of the total memory capacity can push the voltage down
    #  to ~0.90V and save power by a factor of about 1.8X."
    p = SOLVER.solve(VCU128.total_bytes // 2, 1e-6)
    assert p.voltage == pytest.approx(0.90, abs=0.015)
    assert p.savings == pytest.approx(1.8, abs=0.1)


def test_deep_savings_with_capacity_sacrifice():
    # "2.3X power savings is possible by sacrificing some memory space
    #  while the remaining memory space can work with 0% to 50% fault
    #  rate" -- at 0.85 V some PCs are below a 50% rate.
    p = SOLVER.point(0.85, 0.5, VCU128.bytes_per_pc)
    if p is not None:
        assert p.savings == pytest.approx(2.3, abs=0.06)


def test_infeasible_raises():
    with pytest.raises(ValueError):
        SOLVER.solve(VCU128.total_bytes * 2, 0.0)


def test_solution_monotonicity():
    """Looser constraints never yield worse savings (solver invariant)."""
    s_strict = SOLVER.solve(VCU128.total_bytes, 0.0).savings
    s_cap = SOLVER.solve(VCU128.total_bytes // 2, 0.0).savings
    s_rate = SOLVER.solve(VCU128.total_bytes, 1e-4).savings
    assert s_cap >= s_strict - 1e-9
    assert s_rate >= s_strict - 1e-9


def test_fig6_matrix_shape_and_monotonicity():
    rates = [0.0, 1e-7, 1e-5, 1e-3]
    m = SOLVER.fig6_matrix(rates)
    grid = voltage_grid()
    for t in rates:
        assert len(m[t]) == len(grid)
    # at every voltage, a looser tolerance admits >= as many PCs
    for i in range(len(grid)):
        col = [m[t][i] for t in rates]
        assert col == sorted(col)


def test_pareto_frontier():
    pts = SOLVER.pareto(1e-6)
    # savings grow as voltage drops; capacity shrinks (or holds)
    for a, b in zip(pts, pts[1:]):
        assert b.voltage < a.voltage
        assert b.savings >= a.savings
        assert b.capacity_bytes <= a.capacity_bytes
