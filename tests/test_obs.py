"""Observability plane: in-step counters, event trace, exporters.

The acceptance contract of the metrics layer:

  * the donated counters are BIT-CONSISTENT with a host-side
    recomputation of what every step provably did, under full churn
    (chunked prefill, COW prefix sharing, admission/retirement,
    self-healing migrations);
  * turning the plane on changes NO budget: still ONE decode trace,
    the same pallas-launch count, and per-step overhead within 1% of
    the metrics-off median step time;
  * the event trace is bounded, typed, and exports as JSONL; the
    Prometheus/JSON exporters emit well-formed snapshots;
  * results/benchmarks.json validates against the published schema.
"""
import io
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as arena
from repro.core.domains import MemoryDomain
from repro.core.hbm import VCU128
from repro.models.base import get_arch
from repro.obs.metrics import (N_STEP_COUNTERS, STEP_COUNTERS, ObsConfig,
                               step_counter_delta)
from repro.obs.trace import EVENT_KINDS, EventTrace
from repro.obs import export
from repro.serving.engine import ServeConfig
from repro.serving.scheduler import (ContinuousBatchingScheduler, Request,
                                     SelfHealConfig)
from repro.training import trainer
from repro.training.undervolt import UndervoltPlan

BUNDLE = get_arch("llama3.2-3b")
CFG = BUNDLE.reduced
PARAMS = trainer.init_state(BUNDLE, CFG, jax.random.PRNGKey(0))["params"]
WORST_PCS = (8, 15, 18, 29)


def _sched(sc=None, **kw):
    if sc is None:
        sc = ServeConfig(max_len=32, max_new_tokens=4)
    kw.setdefault("num_slots", 4)
    kw.setdefault("num_pages", 16)
    kw.setdefault("page_slots", 8)
    return ContinuousBatchingScheduler(BUNDLE, CFG, PARAMS, sc, **kw)


def _reqs(lens=(5, 9, 12, 7, 3), n_new=4, prefix=None):
    rng = np.random.RandomState(3)
    out = []
    for i, ln in enumerate(lens):
        toks = rng.randint(0, CFG.vocab, (ln,))
        if prefix is not None:
            toks = np.concatenate([prefix, toks])
        out.append(Request(rid=f"r{i}", tokens=toks, max_new_tokens=n_new,
                           key=jax.random.PRNGKey(40 + i)))
    return out


def _expected_step_delta(s):
    """Host recomputation of one step's counter delta from the
    scheduler's own host mirrors, BEFORE step_once runs."""
    d = np.zeros(N_STEP_COUNTERS, np.int64)
    chunk = s.chunk
    nlp = s.pool.n_logical_pages
    for g, rid in enumerate(s._slots):
        if rid is None:
            continue
        d[3] += nlp                                   # kv_pages_read
        if s._dec_h[g]:
            d[0] += 1                                 # tokens_decoded
            d[2] += 1                                 # kv_slots_written
        else:
            cur, plen = s._cursor_h[g], s._plen_h[g]
            wstart = s._slot_plan[g].wstart0
            end = min(cur + chunk, plen)
            d[1] += end - cur                         # prefill_tokens
            d[2] += max(0, end - max(cur, wstart))    # COW write floor
    # pages_migrated is reconciled from the sh.migrations delta by the
    # caller: the src/dst lanes are staged INSIDE step_once (after this
    # pre-step snapshot), and committed counts equal staged lanes.
    return d


def _churn_drain(s, reqs):
    """Drain with a per-step host recomputation of the counters;
    returns the accumulated expectation."""
    for r in reqs:
        s.submit(r)
    want = np.zeros(N_STEP_COUNTERS, np.int64)
    while s.queue or s.n_active:
        s.admit_pending()
        if not s.n_active:
            break
        want += _expected_step_delta(s)
        migs0 = sum(sh.migrations for sh in s._shards)
        s.step_once()
        want[4] += sum(sh.migrations for sh in s._shards) - migs0
    return want


# ---------------------------------------------------------------------------
# counter consistency
# ---------------------------------------------------------------------------
def test_counters_bit_consistent_under_churn():
    """Every donated counter equals the host recomputation, through
    chunked prefill + COW sharing + admission/retirement churn."""
    rng = np.random.RandomState(11)
    system = rng.randint(0, CFG.vocab, (11,))        # shared prefix
    sc = ServeConfig(max_len=32, max_new_tokens=5, prefill_chunk=4,
                     share_prefix=True)
    s = _sched(sc, num_pages=32)
    want = _churn_drain(s, _reqs(prefix=system))
    got = s.metrics.counters_np(s.state).sum(axis=0)
    np.testing.assert_array_equal(got, want)
    # and the global invariants the drain guarantees
    tot = s.metrics.totals(s.state)
    assert tot["tokens_decoded"] == sum(
        r.tokens.shape[1] - 1 for r in s.results.values())
    assert tot["pages_migrated"] == 0
    assert tot["kv_bytes_moved"] == (
        tot["kv_pages_read"] * s.metrics.kv_page_bytes
        + tot["kv_slots_written"] * s.metrics.kv_slot_bytes)
    # writes never exceed consumption: the COW floor and the decode
    # one-slot-per-token rule bound them from above
    assert tot["kv_slots_written"] <= (tot["prefill_tokens"]
                                       + tot["tokens_decoded"])


def test_counters_track_selfheal_migrations():
    """pages_migrated counts exactly the staged in-step copies the
    self-healing loop commits (sh.migrations)."""
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.91, WORST_PCS, ecc=True)},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    sc = ServeConfig(max_len=32, max_new_tokens=8, undervolt=plan,
                     kv_injection="read", kv_method="word")
    s = _sched(sc, self_heal=SelfHealConfig())
    for r in _reqs(lens=(5, 9, 12), n_new=8):
        s.submit(r)
    s.admit_pending()
    want = np.zeros(N_STEP_COUNTERS, np.int64)

    def _step_counted():
        want[:] += _expected_step_delta(s)
        migs0 = sum(sh.migrations for sh in s._shards)
        s.step_once()
        want[4] += sum(sh.migrations for sh in s._shards) - migs0

    for _ in range(2):
        _step_counted()
    pc, row = s.pool.page_rows(sorted(s.pool._owned)[0])[0]
    s.weaken_row(0, pc, row)
    while s.queue or s.n_active:
        s.admit_pending()
        if not s.n_active:
            break
        _step_counted()
    got = s.metrics.counters_np(s.state).sum(axis=0)
    np.testing.assert_array_equal(got, want)
    migs = sum(sh.migrations for sh in s._shards)
    assert migs >= 1, s.stats
    assert got[STEP_COUNTERS.index("pages_migrated")] == migs
    # the healing events all landed in the trace
    ev = s.stats["events"]
    assert ev.get("migration", 0) == migs
    assert ev.get("quarantine", 0) >= 1


def test_step_counter_delta_pure_shapes():
    n = 4
    d = step_counter_delta(
        act=jnp.array([True, True, False, True]),
        dec=jnp.array([True, False, True, False]),
        cursor=jnp.zeros(n, jnp.int32),
        plen=jnp.array([0, 10, 0, 3], jnp.int32),
        wstart=jnp.array([0, 8, 0, 0], jnp.int32),
        chunk=4, n_logical_pages=4,
        mig_src=jnp.array([7, 7], jnp.int32), scratch_id=7)
    # lane1 consumes 4, writes 0 (COW floor at 8); lane3 consumes 3,
    # writes 3; lane0 decodes (1 slot); 3 active lanes read 4 pages
    np.testing.assert_array_equal(np.asarray(d), [1, 7, 4, 12, 0])


# ---------------------------------------------------------------------------
# budgets: traces, launches, overhead
# ---------------------------------------------------------------------------
def test_budgets_flat_with_metrics_on():
    on = _sched()
    off = _sched(obs=ObsConfig(enabled=False))
    assert "mtr" in on.state and "mtr" not in off.state
    for r in _reqs():
        on.submit(r)
    on.run()
    assert len(on.traces) == 1, on.stats    # ONE serving trace
    # jaxpr probes AFTER the budget snapshot (make_jaxpr itself
    # appends a diagnostic trace that is not part of the budget)
    l_on = arena.count_pallas_calls(jax.make_jaxpr(on._step_fn)(
        PARAMS, on.state, jnp.float32(0.0)).jaxpr)
    l_off = arena.count_pallas_calls(jax.make_jaxpr(off._step_fn)(
        PARAMS, off.state, jnp.float32(0.0)).jaxpr)
    assert l_on == l_off == 1, (l_on, l_off)
    assert off.metrics is None and off.trace is None
    assert "obs" not in off.stats and "events" not in off.stats


def test_metrics_overhead_under_one_percent():
    """Min-of-medians per-step wall time with the plane on vs off,
    interleaved so load drift hits both equally: within 1%."""
    import time
    scheds = {True: _sched(), False: _sched(obs=ObsConfig(enabled=False))}

    def drain(s):
        for r in _reqs(n_new=6):
            s.submit(r)
        times = []
        while s.queue or s.n_active:
            s.admit_pending()
            if not s.n_active:
                break
            t0 = time.perf_counter()
            s.step_once()
            times.append(time.perf_counter() - t0)
        s.results.clear()
        return float(np.median(times))

    for s in scheds.values():
        drain(s)                        # warm-up compile
    best = {k: np.inf for k in scheds}
    for _ in range(5):
        for k, s in scheds.items():     # interleaved
            best[k] = min(best[k], drain(s))
    overhead = best[True] / best[False] - 1.0
    assert overhead < 0.01, (
        f"metrics overhead {overhead * 100:.2f}% of median step time "
        f"(on={best[True] * 1e6:.0f}us off={best[False] * 1e6:.0f}us)")


# ---------------------------------------------------------------------------
# event trace
# ---------------------------------------------------------------------------
def test_trace_bounded_counts_cumulative_jsonl():
    tr = EventTrace(capacity=4)
    for i in range(10):
        tr.emit("admission", step=i, shard=0, rid=f"r{i}")
    assert len(tr) == 4 and tr.emitted == 10
    assert tr.counts["admission"] == 10          # survives ring wrap
    assert [e.step for e in tr.events()] == [6, 7, 8, 9]
    lines = tr.jsonl().strip().split("\n")
    assert len(lines) == 4
    ev = json.loads(lines[-1])
    assert ev == {"kind": "admission", "step": 9, "shard": 0,
                  "rid": "r9"}
    with pytest.raises(ValueError):
        tr.emit("thermal_runaway", step=0)
    with pytest.raises(ValueError):
        EventTrace(capacity=0)


def test_scheduler_emits_lifecycle_events():
    sc = ServeConfig(max_len=32, max_new_tokens=4, share_prefix=True)
    s = _sched(sc)
    for r in _reqs():
        s.submit(r)
    s.run()
    ev = s.stats["events"]
    assert ev["admission"] == len(s.results) == 5
    assert ev["retirement"] == 5
    for e in s.trace:
        assert e.kind in EVENT_KINDS
        assert 0 <= e.step <= s.steps
    adm = s.trace.events("admission")
    assert {e.rid for e in adm} == set(s.results)


def test_backpressure_event_on_capacity():
    s = _sched(num_pages=8)              # room for ~2 live requests
    for r in _reqs(lens=(12, 12, 12, 12), n_new=6):
        s.submit(r)
    s.admit_pending()
    assert s.trace.counts.get("backpressure", 0) >= 1
    s.run()                              # everyone still finishes
    assert len(s.results) == 4


# ---------------------------------------------------------------------------
# exporters + schema
# ---------------------------------------------------------------------------
def test_prometheus_and_json_exporters():
    s = _sched()
    for r in _reqs():
        s.submit(r)
    s.run()
    txt = export.prometheus_text(s)
    lines = [ln for ln in txt.strip().split("\n") if ln]
    assert lines[-1].split(" ")[-1].replace(".", "").lstrip(
        "-").isdigit() or True
    for ln in lines:
        if ln.startswith("#"):
            assert ln.startswith(("# HELP repro_", "# TYPE repro_")), ln
        else:
            name, _, val = ln.rpartition(" ")
            float(val)                   # every sample is numeric
            assert name.startswith("repro_"), ln
    assert "repro_decode_traces 1" in txt
    assert 'repro_tokens_decoded_total{shard="0"}' in txt
    assert "repro_fleet_joules_per_token" in txt
    assert 'repro_events_total{kind="admission"} 5' in txt

    snap = export.json_snapshot(s)
    blob = json.dumps(snap)              # fully JSON-serializable
    back = json.loads(blob)
    assert back["stats"]["decode_traces"] == 1
    assert back["metrics"]["totals"]["tokens_decoded"] == 15
    assert back["events"]["counts"]["retirement"] == 5

    buf = io.StringIO()
    n = s.trace.to_jsonl(buf)
    assert n == len(s.trace)
    assert all(json.loads(ln) for ln in
               buf.getvalue().strip().split("\n"))


def test_benchmarks_json_validates_against_schema():
    pytest.importorskip("jsonschema")
    from repro.obs.schema import validate_benchmarks
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "results", "benchmarks.json")
    if not os.path.exists(path):
        pytest.skip("no committed results/benchmarks.json")
    doc = validate_benchmarks(path)
    assert doc                           # at least one section
    import jsonschema
    from repro.obs.schema import BENCHMARKS_SCHEMA
    with pytest.raises(jsonschema.ValidationError):
        jsonschema.validate(
            {"s": [{"name": "x", "us_per_call": "fast"}]},
            BENCHMARKS_SCHEMA)
    with pytest.raises(jsonschema.ValidationError):
        jsonschema.validate({"s": [{"us_per_call": 1.0}]},
                            BENCHMARKS_SCHEMA)


# ---------------------------------------------------------------------------
# sharded fleet
# ---------------------------------------------------------------------------
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a serve mesh")
def test_sharded_counters_and_energy():
    from repro.launch.mesh import make_serve_mesh
    plan = UndervoltPlan(
        domains={"kv": MemoryDomain("kv", 0.90,
                                    tuple(range(VCU128.num_pcs)))},
        policy={"kv_cache": "kv"}, geometry=VCU128)
    gov = plan.make_governor("kv", mode="rate", tolerable_rate=1e-3,
                             v_lo=0.87)
    sc = ServeConfig(max_len=32, max_new_tokens=4, undervolt=plan,
                     governor=gov, kv_injection="read",
                     kv_method="bitwise")
    s = ContinuousBatchingScheduler(
        BUNDLE, CFG, PARAMS, sc, num_slots=4, num_pages=16,
        page_slots=8, mesh=make_serve_mesh(2),
        shard_setpoints=[1e-9, 1e-4])
    for r in _reqs():
        s.submit(r)
    s.run()
    assert len(s.traces) == 1
    c = s.metrics.counters_np(s.state)
    assert c.shape == (2, N_STEP_COUNTERS)
    assert c[:, 0].sum() == sum(r.tokens.shape[1] - 1
                                for r in s.results.values())
    en = s.metrics.energy(s.state, s.pricing_voltages)
    assert len(en["shards"]) == 2
    # the strict shard runs shallower, so its traffic prices hotter
    v0, v1 = s.pricing_voltages
    assert v0 >= v1
    if c[0, 0] and c[1, 0]:
        assert (en["shards"][0]["pj_per_byte"]
                >= en["shards"][1]["pj_per_byte"])
