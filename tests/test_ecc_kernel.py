"""Per-kernel tests: fused inject+ECC kernel vs. oracle + behavior."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.faultmap import PAPER_MAP_SEED, FaultMap
from repro.core.hbm import VCU128
from repro.kernels.bitflip import ops as bops
from repro.kernels.ecc import ops as eops

FMAP = FaultMap.from_seed(VCU128, seed=PAPER_MAP_SEED)


@pytest.mark.parametrize("n", [4096, 8192, 70000, 100])
@pytest.mark.parametrize("v", [0.93, 0.90, 0.88])
def test_kernel_matches_ref(n, v):
    thr = FMAP.thresholds(v, pc=5)
    x = jnp.asarray(np.random.RandomState(1).randint(
        0, 2**31, size=n, dtype=np.int64).astype(np.uint32))
    k, badk = eops.inject_and_correct_u32(x, thresholds=thr, seed=5)
    r, badr = eops.inject_and_correct_u32(x, thresholds=thr, seed=5,
                                          use_ref=True)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))
    assert int(badk) == int(badr)


def test_ecc_corrects_most_faults():
    """SECDED removes all single-bit-per-codeword faults; in the word-path
    regime nearly every faulty codeword has exactly one stuck bit."""
    thr = FMAP.thresholds(0.89, pc=18)
    n = 1 << 20
    x = jnp.zeros((n,), jnp.uint32)
    raw = bops.inject_u32(x, thresholds=thr, seed=5)
    corrected, bad = eops.inject_and_correct_u32(x, thresholds=thr, seed=5)
    raw_faults = int(jnp.sum(raw != x))
    residual = int(jnp.sum(corrected != x))
    assert raw_faults > 50
    assert residual < raw_faults * 0.2
    # residual faulty words come only from uncorrectable codewords
    assert residual <= 2 * int(bad)


def test_guardband_noop():
    thr = FMAP.thresholds(1.0, pc=0)
    x = jnp.asarray(np.arange(8192), jnp.uint32)
    out, bad = eops.inject_and_correct_u32(x, thresholds=thr, seed=5)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    assert int(bad) == 0


def test_uncorrectable_grows_with_depth():
    n = 1 << 19
    x = jnp.zeros((n,), jnp.uint32)
    bads = []
    for v in (0.90, 0.88, 0.86):
        thr = FMAP.thresholds(v, pc=18)
        _, bad = eops.inject_and_correct_u32(x, thresholds=thr, seed=5)
        bads.append(int(bad))
    assert bads[0] <= bads[1] <= bads[2]
    assert bads[2] > bads[0]
