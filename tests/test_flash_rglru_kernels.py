"""Per-kernel tests: flash attention + RG-LRU scan vs pure-jnp oracles,
swept over shapes/dtypes in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops
from repro.kernels.rglru import ops as rops

KEY = jax.random.PRNGKey(7)


def _qkv(b, h, kh, s, d, dtype, key=KEY):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, kh, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, kh, s, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("s,d,g,causal,window", [
    (128, 128, 1, True, 0),
    (256, 128, 4, True, 0),
    (256, 128, 2, True, 64),
    (128, 128, 1, False, 0),
    (192, 128, 1, True, 0),   # non-multiple of block: padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(s, d, g, causal, window, dtype):
    kh = 2
    q, k, v = _qkv(1, kh * g, kh, s, d, dtype)
    out = fops.flash_attention(q, k, v, causal=causal, window=window,
                               bq=128, bkv=128)
    ref = fops.flash_attention(q, k, v, causal=causal, window=window,
                               use_ref=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_attention():
    """Kernel agrees with the training-path (custom-VJP) attention."""
    from repro.models import layers as L
    b, h, kh, s, d = 2, 4, 2, 128, 64
    q, k, v = _qkv(b, h, kh, s, d, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    model_out = L.attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            q_positions=pos, k_positions=pos, causal=True)
    kern_out = fops.flash_attention(q, k, v, causal=True, bq=64, bkv=64)
    np.testing.assert_allclose(
        np.asarray(kern_out.transpose(0, 2, 1, 3), np.float32),
        np.asarray(model_out, np.float32), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,s,r,chunk", [
    (2, 128, 128, 64), (3, 100, 256, 64), (8, 256, 128, 256),
    (1, 64, 384, 32),
])
def test_rglru_matches_ref(b, s, r, chunk):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.random.uniform(k1, (b, s, r), jnp.float32, 0.8, 0.999)
    bb = jax.random.normal(k2, (b, s, r), jnp.float32) * 0.1
    h0 = jax.random.normal(k3, (b, r), jnp.float32)
    out, hlast = rops.rglru_scan(a, bb, h0, chunk=chunk)
    ref, rlast = rops.rglru_scan(a, bb, h0, use_ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(rlast),
                               atol=1e-5, rtol=1e-5)


def test_rglru_matches_model_recurrence():
    """Kernel implements the same recurrence the model layer uses."""
    from repro.models import rglru as R
    from repro.models.base import ArchConfig, init_params
    cfg = ArchConfig(arch_id="t", family="hybrid", n_layers=1, d_model=32,
                     n_heads=4, n_kv_heads=1, d_ff=64, vocab=97,
                     head_dim=8, pattern=("rec",), window=8, lru_width=32,
                     dtype=jnp.float32)
    p = init_params(R.rec_specs(cfg), jax.random.PRNGKey(0))
    y = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    h0 = jnp.zeros((2, 32), jnp.float32)
    hseq, hlast = R._rglru(y, p, h0)
    # extract (a, gated) exactly as the model layer computes them
    yf = y.astype(jnp.float32)
    r_g = jax.nn.sigmoid(yf @ p["w_rg"].astype(jnp.float32) + p["b_rg"])
    i_g = jax.nn.sigmoid(yf @ p["w_ig"].astype(jnp.float32) + p["b_ig"])
    log_a = -R.RGLRU_C * jax.nn.softplus(p["lam"]) * r_g
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i_g * yf)
    out, last = rops.rglru_scan(a, gated, h0, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(hseq),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(last), np.asarray(hlast),
                               atol=1e-5, rtol=1e-5)
